"""Kernel microbenchmarks: XLA reference-path wall times on the host.

interpret=True Pallas timing is emulation (meaningless for TPU), so the
wall numbers here time the XLA paths these kernels replace, sized to the
paper's decode workload; the TPU-relevant throughput claims come from the
dry-run roofline instead. Derived column = bytes touched / time (GB/s proxy).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import time_call
from repro.core import quantization as qz
from repro.core.histogram_topk import histogram_topk
from repro.core.maxpool import maxpool1d_reuse
from repro.kernels.flash_decode.ref import sparse_flash_decode_ref
from repro.kernels.score_est.ref import score_estimate_ref


def _paged_decode_rows(rng, n: int, k: int, pool_factor: int = 64,
                       gate: bool = False) -> list[str]:
    """Paged decode tick: PR 3 pool-wide gather vs the paged-native path.

    One slot holds ``n`` active tokens (logical capacity 2n) inside a pool
    ``pool_factor``·n tokens large — the serving regime, where the shared
    pool backs many other resident requests and dwarfs any one slot's
    context. Three full ticks (scoring + selection + exact attention):

    * ``pr3_gather``  — the shipped PR 3 path, reconstructed inline: the
      exact-attention fetch transposes all four (P·BS, KV, ·) pool buffers
      every tick, so its cost grows with the POOL, not the request;
    * ``gather``      — the cleaned-up fallback (single advanced-index
      row gather, no pool transpose — O(selected) rows moved);
    * ``fused``       — the paged-native path (physical-block streaming on
      TPU; blocked scoring + the row gather on CPU).

    The derived column is the bytes-moved model for the exact-attention
    fetch: pool bytes touched (pr3) vs selected-block bytes (fused) — the
    structural claim; on TPU the transposes are physical data movement. On
    CPU, XLA folds the pr3 transposes into the gather, so tick wall-clock
    mostly reflects how well each whole graph fuses, not bytes. A fifth
    column prices the same tick on a 4-way block-sharded pool
    (`performance_model.sharded_salca_bytes_per_token`): collective psum
    bytes vs per-shard HBM stream. ``gate=True``
    (the --smoke CI run) hard-fails when the fused tick exceeds the pr3 tick
    by >50% at the smoke shapes — a regression tripwire for the fused path
    (it caught two real 6–20× blowups during development), with headroom for
    XLA fusion drift and scheduler noise; the non-smoke run just reports.
    """
    from repro.core import (SalcaParams, empty_paged_cache, prefill_cache,
                            prefill_into_pages)
    from repro.core.attention import (exact_sparse_attention,
                                      salca_decode_attention_paged)
    from repro.core.cache import paged_logical_features, resolve_logical_rows
    from repro.core.selection import (estimate_relevance,
                                      estimate_relevance_paged,
                                      select_sparse_pattern_blocked)
    from repro.kernels.flash_decode.ops import _selected_block_plan

    bsz, kv, hd = 64, 2, 128
    params = SalcaParams(k=k, k_cap=max(((int(k * 1.25) + 127) // 128) * 128, 128),
                         pool_window=7)
    num_blocks = pool_factor * n // bsz
    mb_slot = 2 * n // bsz                 # per-slot logical capacity: 2n
    kk = jnp.asarray(rng.normal(size=(1, n, kv, hd)), jnp.float32)
    vv = jnp.asarray(rng.normal(size=(1, n, kv, hd)), jnp.float32)
    dense = prefill_cache(kk, vv, max_seq=mb_slot * bsz, params=params)
    pool = empty_paged_cache(num_blocks, bsz, 1, mb_slot, kv, hd,
                             params.r(hd))
    need = n // bsz
    pages = np.full(mb_slot, -1, np.int32)
    pages[:need] = rng.choice(num_blocks, need, replace=False)
    pool = prefill_into_pages(pool, dense, 0, jnp.asarray(pages))
    q = jnp.asarray(rng.normal(size=(1, 2 * kv, hd)), jnp.float32)

    def pr3_gather(pool, sel):  # the four pool-wide transposes, verbatim
        phys = resolve_logical_rows(pool, sel.indices)

        def take_codes(codes):
            flat = codes.reshape((-1,) + codes.shape[2:])
            f = flat.transpose(1, 0, 2)
            return jnp.take_along_axis(f[None], phys[..., None], axis=2)

        def take_scale(scale):
            flat = scale.reshape((-1,) + scale.shape[2:])
            f = flat.transpose(1, 0)
            return jnp.take_along_axis(f[None], phys, axis=2)

        return (take_codes(pool.k_codes), take_scale(pool.k_scale),
                take_codes(pool.v_codes), take_scale(pool.v_scale))

    def pr3_tick(q, pool):
        b, h, _ = q.shape
        groups = h // pool.num_kv_heads
        r_ = pool.heavy_idx.shape[-1]
        idx = jnp.broadcast_to(pool.heavy_idx[:, :, None, :],
                               (b, pool.num_kv_heads, groups, r_))
        qg = q.reshape(b, pool.num_kv_heads, groups, hd).astype(jnp.float32)
        q_feat = jnp.take_along_axis(qg, idx, axis=-1).reshape(b, h, r_)
        fw, fs, fz = paged_logical_features(pool)
        scores = estimate_relevance(q_feat, fw, fs, fz, groups)
        sel = select_sparse_pattern_blocked(scores, params,
                                            pool.valid_mask()[:, None, :],
                                            pool.block_size)
        kc, ks, vc, vs = pr3_gather(pool, sel)
        return exact_sparse_attention(q, kc, ks, vc, vs, sel.mask)

    ticks = {
        "paged_decode_pr3_gather": jax.jit(pr3_tick),
        "paged_decode_gather": jax.jit(
            lambda q, p: salca_decode_attention_paged(q, p, params, fused=False)),
        "paged_decode_fused": jax.jit(
            lambda q, p: salca_decode_attention_paged(q, p, params, fused=True)),
    }
    # Bytes-moved model for the exact-attention fetch (codes + scales, K+V):
    pool_bytes = (pool.k_codes.size + pool.v_codes.size
                  + 4 * pool.k_scale.size + 4 * pool.v_scale.size)

    @jax.jit
    def selection_only(q, pool):  # scoring + selection, no attention
        b, h, _ = q.shape
        groups = h // pool.num_kv_heads
        r_ = pool.heavy_idx.shape[-1]
        idx = jnp.broadcast_to(pool.heavy_idx[:, :, None, :],
                               (b, pool.num_kv_heads, groups, r_))
        qg = q.reshape(b, pool.num_kv_heads, groups, hd).astype(jnp.float32)
        q_feat = jnp.take_along_axis(qg, idx, axis=-1).reshape(b, h, r_)
        scores = estimate_relevance_paged(q_feat, pool, groups)
        return select_sparse_pattern_blocked(scores, params,
                                             pool.valid_mask()[:, None, :],
                                             pool.block_size)

    sel = selection_only(q, pool)
    _, counts, _ = _selected_block_plan(pool, sel)
    sel_blocks = int(np.asarray(counts).sum())
    sel_bytes = sel_blocks * bsz * (2 * hd + 8)    # per-head block K+V bytes
    model = {"paged_decode_pr3_gather": f"{pool_bytes/1e6:.1f}MB_pool_fetch",
             "paged_decode_gather": "O(selected)_row_fetch",
             "paged_decode_fused":
                 f"{sel_bytes/1e6:.2f}MB_selected({pool_bytes/max(sel_bytes,1):.0f}x_less)"}
    # Interconnect column: what the same tick costs in COLLECTIVE bytes when
    # the pool is sharded 4 ways (psum'd histogram threshold + halo + rank +
    # the (m, l, o) softmax merge — context-length-independent) next to the
    # per-shard HBM stream. The ratio is the headroom argument for the
    # sharded engine: the mesh term stays O(max_blocks + 256 + d) while the
    # streamed slice keeps growing with context.
    from repro.core.performance_model import sharded_salca_bytes_per_token
    sh = sharded_salca_bytes_per_token(
        n=n, d=hd, kv_heads=kv, groups=2, s_f=0.5, retention=k / n,
        n_shards=4, block_size=bsz)
    shard_col = (f"shard4:{sh.interconnect/1e3:.1f}KB_psum_vs_"
                 f"{sh.local_total/1e6:.2f}MB_local"
                 f"({100 * sh.interconnect_ratio:.1f}%)")
    rows, us = [], {}
    for name, fn in ticks.items():
        us[name] = time_call(fn, q, pool)
        rows.append(f"kernel_bench,{name},{us[name]:.1f},{model[name]},"
                    f"{shard_col}")
    # Ratio gate with an absolute-delta floor: a loaded CI runner can stretch
    # a ~2ms median by tens of percent, but a real fused-path regression (the
    # 6–20× class this tripwire caught in development) blows past both.
    if gate and (us["paged_decode_fused"] > 1.5 * us["paged_decode_pr3_gather"]
                 and us["paged_decode_fused"]
                 > us["paged_decode_pr3_gather"] + 2000):
        raise RuntimeError(
            f"paged-native decode tick ({us['paged_decode_fused']:.0f}us) is "
            f"slower than the pool-wide gather tick "
            f"({us['paged_decode_pr3_gather']:.0f}us) at pool="
            f"{num_blocks * bsz} tokens — the fusion regressed")
    return rows


def _sharded_decode_rows(rng, n: int, k: int, gate: bool = False) -> list[str]:
    """Sharded island tick: the PR 5 gather island vs the fully-pipelined
    fused island, on a 1-way mesh (CPU wall times; the structural claim is
    the bytes-moved model column).

    * ``sharded_island_legacy`` — `sp_salca_decode_paged(fused=False)`:
      every tick re-materializes capacity-shaped logical copies of all seven
      pool leaves (`performance_model.sharded_gather_bytes_per_token`);
    * ``sharded_island_fused``  — the fused island: two kernel passes over
      owned-active blocks + the selected-block fetch, two psums
      (`performance_model.sharded_fused_bytes_per_token`).

    ``gate=True`` (the --smoke CI run) hard-fails if (a) the per-shard
    bytes-moved model ratio falls under 10× at a 4-way shard, or (b) the
    fused tick's measured wall time regresses past the legacy tick with the
    same ratio+absolute-delta noise guard the paged gate uses.
    """
    from jax.sharding import PartitionSpec as P

    from repro import compat
    from repro.core import (SalcaParams, empty_paged_cache, prefill_cache,
                            prefill_into_pages)
    from repro.core.performance_model import (
        sharded_fused_bytes_per_token, sharded_gather_bytes_per_token)
    from repro.core.sp_decode import sp_salca_decode_paged

    bsz, kv, hd = 64, 2, 128
    params = SalcaParams(k=k, k_cap=max(((int(k * 1.25) + 127) // 128) * 128,
                                        128), pool_window=7)
    mb_slot = 2 * n // bsz                 # per-slot logical capacity: 2n
    num_blocks = 4 * n // bsz
    kk = jnp.asarray(rng.normal(size=(1, n, kv, hd)), jnp.float32)
    vv = jnp.asarray(rng.normal(size=(1, n, kv, hd)), jnp.float32)
    dense = prefill_cache(kk, vv, max_seq=mb_slot * bsz, params=params)
    pool = empty_paged_cache(num_blocks, bsz, 1, mb_slot, kv, hd,
                             params.r(hd))
    need = n // bsz
    pages = np.full(mb_slot, -1, np.int32)
    pages[:need] = rng.choice(num_blocks, need, replace=False)
    pool = prefill_into_pages(pool, dense, 0, jnp.asarray(pages))
    q = jnp.asarray(rng.normal(size=(1, 2 * kv, hd)), jnp.float32)
    mesh = compat.make_mesh((1,), ("seq",))

    def island(fused):
        def f(q_, p_):
            return sp_salca_decode_paged(q_, p_, params, "seq", fused=fused)
        return jax.jit(compat.shard_map(f, mesh, in_specs=(P(), P()),
                                        out_specs=P(), check_vma=False))

    leg = sharded_gather_bytes_per_token(
        n=n, d=hd, kv_heads=kv, groups=2, s_f=0.5, retention=k / n,
        n_shards=4, block_size=bsz, max_blocks=mb_slot, slots=1)
    fus = sharded_fused_bytes_per_token(
        n=n, d=hd, kv_heads=kv, groups=2, s_f=0.5, retention=k / n,
        n_shards=4, block_size=bsz)
    ratio = leg.local_total / max(fus.local_total, 1e-9)
    model = {
        "sharded_island_legacy":
            f"shard4:{leg.local_total/1e6:.2f}MB_capacity_copies",
        "sharded_island_fused":
            f"shard4:{fus.local_total/1e3:.1f}KB_owned+selected"
            f"({ratio:.0f}x_less)",
    }
    rows, us = [], {}
    for name, fused in (("sharded_island_legacy", False),
                        ("sharded_island_fused", True)):
        us[name] = time_call(island(fused), q, pool)
        rows.append(f"kernel_bench,{name},{us[name]:.1f},{model[name]}")
    if gate:
        if ratio < 10.0:
            raise RuntimeError(
                f"sharded fused bytes-moved model ratio {ratio:.1f}x < 10x "
                f"at n={n} — the fused island's traffic model regressed")
        if (us["sharded_island_fused"] > 1.5 * us["sharded_island_legacy"]
                and us["sharded_island_fused"]
                > us["sharded_island_legacy"] + 2000):
            raise RuntimeError(
                f"fused sharded tick ({us['sharded_island_fused']:.0f}us) is "
                f"slower than the legacy gather tick "
                f"({us['sharded_island_legacy']:.0f}us) — the island fusion "
                f"regressed")
    return rows


def run(n: int = 32768, bh: int = 8, r: int = 64, k: int = 1024,
        paged_gate: bool = False) -> list[str]:
    rng = np.random.default_rng(0)
    rows = ["kernel_bench,name,us_per_call,derived"]

    kf = jnp.asarray(rng.normal(size=(bh, n, r)), jnp.float32)
    k2 = qz.quantize_key_features(kf)
    words = qz.pack2bit(k2.codes)
    qf = jnp.asarray(rng.normal(size=(bh, 4, r)), jnp.float32)
    q3 = qz.quantize_query_features(qf)
    f = jax.jit(score_estimate_ref)
    us = time_call(f, q3.codes, q3.scale, words, k2.scale, k2.zero)
    bytes_read = words.size * 4 + k2.scale.size * 8
    rows.append(f"kernel_bench,score_est,{us:.1f},{bytes_read/us/1e3:.2f}GB/s")

    bins = jnp.asarray(rng.integers(1, 256, size=(bh, n)), jnp.uint8)
    f = jax.jit(lambda b: histogram_topk(b, k, k_cap=int(k * 1.25) // 128 * 128))
    us = time_call(f, bins)
    rows.append(f"kernel_bench,hist_topk,{us:.1f},{bins.size/us/1e3:.2f}Gelem/s")

    f = jax.jit(lambda b: maxpool1d_reuse(b, 7))
    us = time_call(f, bins)
    rows.append(f"kernel_bench,maxpool_w7,{us:.1f},{bins.size/us/1e3:.2f}Gelem/s")

    c = int(k * 1.25) // 128 * 128
    kc = jnp.asarray(rng.integers(-127, 128, size=(bh, c, 128)), jnp.int8)
    vc = jnp.asarray(rng.integers(-127, 128, size=(bh, c, 128)), jnp.int8)
    ks = jnp.asarray(rng.random((bh, c)), jnp.float32)
    vs = jnp.asarray(rng.random((bh, c)), jnp.float32)
    mask = jnp.ones((bh, c), bool)
    qd = jnp.asarray(rng.normal(size=(bh, 4, 128)), jnp.float32)
    f = jax.jit(sparse_flash_decode_ref)
    us = time_call(f, qd, kc, ks, vc, vs, mask)
    rows.append(f"kernel_bench,flash_decode,{us:.1f},{(kc.size+vc.size)/us/1e3:.2f}GB/s")

    # end-to-end salca decode step vs dense decode (XLA, host CPU)
    from repro.core import SalcaParams, prefill_cache, salca_decode_attention
    from repro.core.attention import dense_decode_from_cache
    B, T, H, KV, HD = 1, n, 8, 8, 128
    kk = jnp.asarray(rng.normal(size=(B, T, KV, HD)), jnp.float32)
    vv = jnp.asarray(rng.normal(size=(B, T, KV, HD)), jnp.float32)
    params = SalcaParams.for_seq(T, retention=0.05)
    cache = prefill_cache(kk, vv, max_seq=T, params=params)
    q = jnp.asarray(rng.normal(size=(B, H, HD)), jnp.float32)
    f_salca = jax.jit(lambda q, c: salca_decode_attention(q, c, params))
    f_dense = jax.jit(dense_decode_from_cache)
    us_s = time_call(f_salca, q, cache)
    us_d = time_call(f_dense, q, cache)
    rows.append(f"kernel_bench,salca_decode_e2e,{us_s:.1f},{us_d/us_s:.2f}x_vs_dense")
    rows.append(f"kernel_bench,dense_decode_e2e,{us_d:.1f},1.00x")

    # paged decode tick: PR 3 pool-wide gather vs the paged-native fused path
    # (paged_gate=True — the --smoke CI run — hard-fails if the fused tick
    # regresses past the pool-wide gather tick)
    rows.extend(_paged_decode_rows(rng, n=min(n, 4096), k=k, gate=paged_gate))
    # sharded island tick: legacy capacity-shaped gather vs the fully-
    # pipelined fused island (paged_gate=True also hard-fails if the model
    # bytes ratio drops under 10x or the fused tick regresses past legacy)
    rows.extend(_sharded_decode_rows(rng, n=min(n, 2048), k=min(k, 512),
                                     gate=paged_gate))
    return rows


def main() -> None:
    for row in run():
        print(row)


if __name__ == "__main__":
    main()
