"""Kernel microbenchmarks: XLA reference-path wall times on the host.

interpret=True Pallas timing is emulation (meaningless for TPU), so the
wall numbers here time the XLA paths these kernels replace, sized to the
paper's decode workload; the TPU-relevant throughput claims come from the
dry-run roofline instead. Derived column = bytes touched / time (GB/s proxy).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import time_call
from repro.core import quantization as qz
from repro.core.histogram_topk import histogram_topk
from repro.core.maxpool import maxpool1d_reuse
from repro.kernels.flash_decode.ref import sparse_flash_decode_ref
from repro.kernels.score_est.ref import score_estimate_ref


def run(n: int = 32768, bh: int = 8, r: int = 64, k: int = 1024) -> list[str]:
    rng = np.random.default_rng(0)
    rows = ["kernel_bench,name,us_per_call,derived"]

    kf = jnp.asarray(rng.normal(size=(bh, n, r)), jnp.float32)
    k2 = qz.quantize_key_features(kf)
    words = qz.pack2bit(k2.codes)
    qf = jnp.asarray(rng.normal(size=(bh, 4, r)), jnp.float32)
    q3 = qz.quantize_query_features(qf)
    f = jax.jit(score_estimate_ref)
    us = time_call(f, q3.codes, q3.scale, words, k2.scale, k2.zero)
    bytes_read = words.size * 4 + k2.scale.size * 8
    rows.append(f"kernel_bench,score_est,{us:.1f},{bytes_read/us/1e3:.2f}GB/s")

    bins = jnp.asarray(rng.integers(1, 256, size=(bh, n)), jnp.uint8)
    f = jax.jit(lambda b: histogram_topk(b, k, k_cap=int(k * 1.25) // 128 * 128))
    us = time_call(f, bins)
    rows.append(f"kernel_bench,hist_topk,{us:.1f},{bins.size/us/1e3:.2f}Gelem/s")

    f = jax.jit(lambda b: maxpool1d_reuse(b, 7))
    us = time_call(f, bins)
    rows.append(f"kernel_bench,maxpool_w7,{us:.1f},{bins.size/us/1e3:.2f}Gelem/s")

    c = int(k * 1.25) // 128 * 128
    kc = jnp.asarray(rng.integers(-127, 128, size=(bh, c, 128)), jnp.int8)
    vc = jnp.asarray(rng.integers(-127, 128, size=(bh, c, 128)), jnp.int8)
    ks = jnp.asarray(rng.random((bh, c)), jnp.float32)
    vs = jnp.asarray(rng.random((bh, c)), jnp.float32)
    mask = jnp.ones((bh, c), bool)
    qd = jnp.asarray(rng.normal(size=(bh, 4, 128)), jnp.float32)
    f = jax.jit(sparse_flash_decode_ref)
    us = time_call(f, qd, kc, ks, vc, vs, mask)
    rows.append(f"kernel_bench,flash_decode,{us:.1f},{(kc.size+vc.size)/us/1e3:.2f}GB/s")

    # end-to-end salca decode step vs dense decode (XLA, host CPU)
    from repro.core import SalcaParams, prefill_cache, salca_decode_attention
    from repro.core.attention import dense_decode_from_cache
    B, T, H, KV, HD = 1, n, 8, 8, 128
    kk = jnp.asarray(rng.normal(size=(B, T, KV, HD)), jnp.float32)
    vv = jnp.asarray(rng.normal(size=(B, T, KV, HD)), jnp.float32)
    params = SalcaParams.for_seq(T, retention=0.05)
    cache = prefill_cache(kk, vv, max_seq=T, params=params)
    q = jnp.asarray(rng.normal(size=(B, H, HD)), jnp.float32)
    f_salca = jax.jit(lambda q, c: salca_decode_attention(q, c, params))
    f_dense = jax.jit(dense_decode_from_cache)
    us_s = time_call(f_salca, q, cache)
    us_d = time_call(f_dense, q, cache)
    rows.append(f"kernel_bench,salca_decode_e2e,{us_s:.1f},{us_d/us_s:.2f}x_vs_dense")
    rows.append(f"kernel_bench,dense_decode_e2e,{us_d:.1f},1.00x")
    return rows


def main() -> None:
    for row in run():
        print(row)


if __name__ == "__main__":
    main()
