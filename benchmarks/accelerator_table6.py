"""Paper Table 6: comparison with existing accelerators under LCS.

Reproduces the paper's *equivalent-evaluation* methodology for re-scoring
short-context accelerators at 64k context (§5.3):

    throughput:  T_LCS = T_SCS / Parallelism_q      (decode is one query)
    IO power:    P_HBM = (freq/500MHz)·(Mult/M_Salca)·P_SalcaIO
    area:        A_LCS = A_SCS + A_buf               (128K-entry buffer)

Published SCS numbers are taken from the paper's own table (they cite each
accelerator's original publication); the LCS-adjusted values are recomputed
here and checked against the paper's "after-slash" numbers where printed.
"""

from __future__ import annotations

from dataclasses import dataclass

SALCA_IO_W = 9.83        # paper: Salca IO power (28nm-scaled)
SALCA_FREQ_MHZ = 500
A_BUF_MM2 = 2.0          # ≈128K-entry INT8 buffer at 28 nm (paper's A_buf)


@dataclass(frozen=True)
class Accel:
    name: str
    maxlen: int
    tput_scs: float          # GOPS as published (SCS)
    core_w: float
    freq_mhz: float
    area_scs_mm2: float      # scaled to 28 nm (paper's col)
    parallelism_q: float     # query-level parallelism exploited in prefill
    mult_ratio: float        # multiplier count / M_Salca
    paper_tput_lcs: float | None = None   # the paper's after-slash value


ACCELS = [
    Accel("A3", 320, 221, 0.205, 1000, 2.08, 1, 0.6),
    Accel("ELSA", 512, 1090, 0.969, 1000, 1.26, 1, 2.0),
    Accel("Sanger", 4096, 2285, 2.76, 500, 16.9, 64, 0.25, paper_tput_lcs=36),
    Accel("DOTA", 4096, 4905, 3.02, 1000, 4.44, 4, 0.72, paper_tput_lcs=1226),
    Accel("Energon", 1024, 1153, 0.32, 1000, 4.20, 1, 2.3),
    Accel("SpAtten", 1024, 360, 0.325, 1000, 1.55, 1, 1.26),
    Accel("FACT", 512, 928, 0.337, 500, 6.03, 1, 0.94),
    Accel("SOFA", 4096, 24428, 0.95, 1000, 5.69, 128, 1.46, paper_tput_lcs=191),
]

SALCA = Accel("Salca", 65536, 4350, 0.933, 500, 6.4, 1, 1.0)


def lcs_adjust(a: Accel) -> dict:
    tput = a.tput_scs / a.parallelism_q
    io_w = (a.freq_mhz / SALCA_FREQ_MHZ) * a.mult_ratio * SALCA_IO_W
    area = a.area_scs_mm2 + (A_BUF_MM2 if a.name != "Salca" else 0.0)
    return {
        "tput_gops": tput,
        "core_eff": tput / a.core_w,
        "dev_eff": tput / (a.core_w + io_w),
        "area_eff": tput / area,
    }


def run() -> list[str]:
    rows = ["table6_accel,name,maxlen,tput_lcs,core_eff,dev_eff,area_eff"]
    sal = lcs_adjust(SALCA)
    best = {k: 0.0 for k in sal}
    for a in ACCELS:
        m = lcs_adjust(a)
        for k in best:
            best[k] = max(best[k], m[k])
        rows.append(f"table6_accel,{a.name},{a.maxlen},{m['tput_gops']:.0f},"
                    f"{m['core_eff']:.0f},{m['dev_eff']:.0f},{m['area_eff']:.0f}")
    rows.append(f"table6_accel,Salca,{SALCA.maxlen},{sal['tput_gops']:.0f},"
                f"{sal['core_eff']:.0f},{sal['dev_eff']:.0f},{sal['area_eff']:.0f}")
    rows.append(f"table6_margin,throughput,{sal['tput_gops']/best['tput_gops']:.2f}x,"
                "paper claims ≥3.5x")
    rows.append(f"table6_margin,device_eff,{sal['dev_eff']/best['dev_eff']:.2f}x,"
                "paper claims ≥2.08x")
    return rows


def main() -> None:
    for row in run():
        print(row)


if __name__ == "__main__":
    main()
