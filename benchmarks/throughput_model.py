"""Paper Figs. 9/10 proxy: decode throughput & gain breakdown from the
§4.4 performance model (plus the TPU bytes model).

Since real hardware is absent, throughput comes from the validated
analytical model (tests pin it to the paper's operating point):

    ASIC_D       dense INT8 attention, all n keys streamed
    ASIC_S_4     4-bit full-feature filter (Energon/Sanger-style),
                 min retention limited to 13% (paper's analysis)
    Salca(2%/1%) dual compression + O(n) top-k at 5%/9.4% retention bands
                 with/without conflict elimination (α 2.18 → 1.17)

Outputs normalized decode throughput (vs ASIC_D) and the multiplicative
gain split into sparse-method gain × conflict-elimination gain, mirroring
Fig. 10a.
"""

from __future__ import annotations

from repro.core import performance_model as pm


def decode_time_model(hw: pm.HardwareSpec, n: int, s_f: float, retention: float,
                      m_pre: int, m_att: int, alpha: float) -> float:
    """Per-head decode time (compute cycles) under the paper's pipeline."""
    hw = pm.HardwareSpec(d=hw.d, chn=hw.chn, bw_bits=hw.bw_bits, f_cmp=hw.f_cmp,
                         f_hbm=hw.f_hbm, alpha=alpha, beta_pre=hw.beta_pre,
                         beta_att=hw.beta_att)
    return pm.decode_cycles(hw, n, retention, m_pre, m_att)


def dense_time(hw: pm.HardwareSpec, n: int) -> float:
    """All K/V streamed at INT8 through the full attention bandwidth."""
    m_att_dense = int(pm.bandwidth_bits_per_cycle(hw) / pm.att_bits_per_key(hw.d))
    return n / (hw.beta_pre * m_att_dense)   # sequential stream: β_pre


def run(n: int = 65536) -> list[str]:
    hw = pm.HardwareSpec()
    rows = ["fig9_throughput,config,rel_throughput,notes"]
    t_dense = dense_time(hw, n)
    rows.append(f"fig9_throughput,ASIC_D,1.00,dense INT8 stream")

    # 4-bit filter baseline: feature stream = (4d+32) bits; retention 13%.
    bw = pm.bandwidth_bits_per_cycle(hw)
    four_bits = 4 * hw.d + 32
    m_att = 2
    m_pre4 = int((bw - pm.att_bits_per_key(hw.d) * m_att) / four_bits)
    t4 = max(n / (hw.beta_pre * m_pre4),
             n * 0.13 * hw.alpha / (hw.beta_att * m_att))
    rows.append(f"fig9_throughput,ASIC_S_4,{t_dense / t4:.2f},4-bit filter r=13%")

    # Salca at the paper's two accuracy bands, with/without reordering, at
    # the PAPER's operating point (p_pre=16 ⇒ m_pre=17; p_att=1 ⇒ m_att=2 —
    # §4.4's final design, validated in tests).
    m_pre, m_att = 17, 2
    for tag, r_q in (("Salca(2%)", 0.058), ("Salca(1%)", 0.094)):
        t_no = decode_time_model(hw, n, 0.5, r_q, m_pre, m_att, alpha=2.18)
        t_yes = decode_time_model(hw, n, 0.5, r_q, m_pre, m_att, alpha=1.17)
        rows.append(f"fig9_throughput,{tag}_noreorder,{t_dense / t_no:.2f},alpha=2.18")
        rows.append(f"fig9_throughput,{tag},{t_dense / t_yes:.2f},alpha=1.17")

    # Fig 10a-style breakdown at the 2% band.
    t_salca = decode_time_model(hw, n, 0.5, 0.058, m_pre, m_att, 1.17)
    t_salca_conf = decode_time_model(hw, n, 0.5, 0.058, m_pre, m_att, 2.18)
    sparse_gain = t_dense / t_salca_conf
    conflict_gain = t_salca_conf / t_salca
    rows.append(f"fig10_breakdown,sparse_method_gain,{sparse_gain:.2f},paper 2.58x")
    rows.append(f"fig10_breakdown,conflict_elim_gain,{conflict_gain:.2f},paper 1.87x")
    rows.append(f"fig10_breakdown,total_gain,{t_dense / t_salca:.2f},paper ~4.8x over ASIC_D")

    # TPU bytes model: per-token HBM traffic, dense vs salca (roofline view).
    dense_b = pm.dense_bytes_per_token(n, 128, 8, dtype_bytes=1.0)   # int8 dense
    salca_b = pm.salca_bytes_per_token(n, 128, 8, 0.5, 0.05)
    rows.append(f"fig9_tpu_bytes,dense_int8,{dense_b.total/1e6:.2f}MB,per token/layer")
    rows.append(f"fig9_tpu_bytes,salca,{salca_b.total/1e6:.2f}MB,"
                f"{dense_b.total/salca_b.total:.1f}x reduction")
    return rows


def main() -> None:
    for row in run():
        print(row)


if __name__ == "__main__":
    main()
