"""§Roofline table assembly from the dry-run JSON store."""

from __future__ import annotations

import glob
import json
import os

RESULTS = os.environ.get("REPRO_DRYRUN_DIR", "results/dryrun")


def load_cells(granularity: str = "layer", mesh: str = "single") -> list[dict]:
    cells = []
    for path in sorted(glob.glob(os.path.join(
            RESULTS, f"*__{mesh}__{granularity}.json"))):
        with open(path) as f:
            cells.append(json.load(f))
    return cells


def run() -> list[str]:
    rows = ["roofline,arch,shape,mesh,compute_s,memory_s,collective_s,"
            "bottleneck,useful_ratio,roofline_fraction"]
    cells = load_cells()
    for c in cells:
        if c.get("status") != "ok" or "roofline" not in c:
            rows.append(f"roofline,{c.get('arch')},{c.get('shape')},"
                        f"{c.get('mesh')},ERROR,,,,,")
            continue
        r = c["roofline"]
        rows.append(
            f"roofline,{c['arch']},{c['shape']},{c['mesh']},"
            f"{r['compute_s']:.3e},{r['memory_s']:.3e},{r['collective_s']:.3e},"
            f"{r['bottleneck']},{r['useful_ratio']:.3f},{r['roofline_fraction']:.4f}")
    if len(cells) == 0:
        rows.append("roofline,NO_RESULTS,run launch.dryrun --granularity layer first,,,,,,,")
    return rows


def main() -> None:
    for row in run():
        print(row)


if __name__ == "__main__":
    main()
