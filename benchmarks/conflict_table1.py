"""Paper Table 1: HBM channel-conflict ratio vs reorder range.

Reproduces the reorder-based conflict-elimination evaluation with the
simulator in `core.conflict_sim`, for both uniform-random and Salca-realistic
run-structured index streams.
"""

from __future__ import annotations

from repro.core import conflict_sim as cs

PAPER = {8: 2.18, 16: 1.71, 32: 1.45, 64: 1.25, 128: 1.17, 256: 1.09}


def run() -> list[str]:
    rows = []
    uni = cs.conflict_table(structured=False, total=1 << 18, seed=0)
    runs = cs.conflict_table(structured=True, total=1 << 18, seed=0)
    rows.append("table1_conflict,range,uniform,structured,paper")
    for r in (8, 16, 32, 64, 128, 256):
        rows.append(f"table1_conflict,{r},{uni[r]:.3f},{runs[r]:.3f},{PAPER[r]:.2f}")
    return rows


def main() -> None:
    for row in run():
        print(row)


if __name__ == "__main__":
    main()
