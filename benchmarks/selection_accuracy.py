"""Paper Tables 3/4 proxy: sparse-pattern selection quality across methods.

LongBench + the 7B chat models aren't available offline, so the comparison
runs at mechanism level on synthetic-but-structured attention (concentrated
relevance in coherent runs + heavy-channel keys): for each method we report
the paper's Table-4 metrics — overlap with the true top-K, coverage of the
true top-K/2 — plus attention-output relative error.

Methods:
    salca      dual compression (2-bit asym K features × 3-bit sym Q)
               + maxpool + histogram top-k          [the paper]
    pl_topk    full-precision scores + maxpool + exact top-k  [upper band]
    std_topk   full-precision scores + exact top-k
    loki       offline (calibration) channel selection, same budget
    h2o        accumulated-score heuristic (history mass)
    snapkv     observation-window (suffix) voting + pooling
    moba       block-mean relevance, whole-block selection
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import (attention_output_error, overlap_coverage,
                               synthetic_attention_case, true_scores)
from repro.core import SalcaParams, prefill_cache, salca_decode_attention
from repro.core.heavy_channels import extract_channels, static_channel_indices
from repro.core.histogram_topk import Selection, compact_indices
from repro.core.maxpool import maxpool1d_reuse


def _topk_selection(scores, k, k_cap, pool=0):
    s = scores
    if pool:
        s = maxpool1d_reuse(s, pool)
    thr = jnp.sort(s, axis=-1)[..., -k][..., None]
    keep = s >= thr
    idx, mask, count = compact_indices(keep, k_cap)
    return Selection(idx, mask, count, jnp.zeros(s.shape[:-1], jnp.int32))


def run(seed: int = 0, T: int = 2048, retention: float = 0.08) -> list[str]:
    q, k, v, _ = synthetic_attention_case(seed, T=T)
    B, KV = k.shape[0], k.shape[2]
    s_true = true_scores(q, k)
    kk = max(64, int(T * retention))
    k_cap = int(kk * 1.25) // 128 * 128 + 128
    out = ["table34_selection,method,overlap,coverage,attn_rel_err"]

    def report(name, sel):
        ov, cov = overlap_coverage(sel.indices, sel.mask, s_true, k_top=kk)
        err = attention_output_error(q, k, v, sel.indices, sel.mask)
        out.append(f"table34_selection,{name},{ov:.3f},{cov:.3f},{err:.3f}")

    # --- Salca (the paper) -------------------------------------------------
    for pool, tag in ((True, "salca"), (False, "salca_nopool")):
        params = SalcaParams(feature_sparsity=0.5, k=kk, k_cap=k_cap,
                             use_pool=pool)
        cache = prefill_cache(k, v, max_seq=T, params=params)
        _, sel = salca_decode_attention(q, cache, params, return_selection=True)
        report(tag, sel)

    # --- full-precision exact top-k bands ----------------------------------
    report("pl_topk", _topk_selection(s_true, kk, k_cap, pool=7))
    report("std_topk", _topk_selection(s_true, kk, k_cap))

    # --- Loki-style offline channels ----------------------------------------
    rng = np.random.default_rng(seed + 1)
    calib = jnp.asarray(rng.normal(size=(B, 256, KV, k.shape[-1])), jnp.float32)
    kt = k.transpose(0, 2, 1, 3)
    idx_static = static_channel_indices(
        calib.transpose(0, 2, 1, 3).reshape(B, KV, 256, -1), 32)
    G = q.shape[1] // KV
    qg = q.reshape(B, KV, G, -1)
    qf = extract_channels(qg, idx_static)
    kf = extract_channels(kt, idx_static)
    s_loki = jnp.einsum("bkgr,bktr->bkt", qf, kf)
    report("loki", _topk_selection(s_loki, kk, k_cap))

    # --- H2O-style: historical attention mass -------------------------------
    w = jnp.asarray(rng.normal(size=(B, 8, q.shape[1])), jnp.float32)
    hist_q = jnp.einsum("bjh,bhd->bjd", w, q)   # pseudo past queries
    s_hist = jnp.einsum("bjd,btkd->bkt",
                        hist_q, k) / jnp.sqrt(k.shape[-1])
    report("h2o", _topk_selection(s_hist, kk, k_cap))

    # --- SnapKV-style: suffix-window scores + pooling -----------------------
    s_snap = maxpool1d_reuse(s_hist, 7)
    report("snapkv", _topk_selection(s_snap, kk, k_cap))

    # --- MoBA-style: block-level selection -----------------------------------
    bs = 16
    s_blocks = s_true.reshape(B, KV, T // bs, bs).mean(-1)
    blk_thr = jnp.sort(s_blocks, axis=-1)[..., -(kk // bs)][..., None]
    keep = jnp.repeat(s_blocks >= blk_thr, bs, axis=-1)
    idx, mask, count = compact_indices(keep, k_cap)
    report("moba", Selection(idx, mask, count, jnp.zeros((B, KV), jnp.int32)))
    return out


def main() -> None:
    for row in run():
        print(row)


if __name__ == "__main__":
    main()
