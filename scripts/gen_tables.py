"""Generate EXPERIMENTS.md markdown tables from the dry-run JSON store.

    PYTHONPATH=src python scripts/gen_tables.py [results/dryrun]
"""

from __future__ import annotations

import glob
import json
import os
import sys

RES = sys.argv[1] if len(sys.argv) > 1 else "results/dryrun"


def load(pattern):
    out = []
    for p in sorted(glob.glob(os.path.join(RES, pattern))):
        with open(p) as f:
            out.append(json.load(f))
    return out


def fmt(x, n=3):
    return f"{x:.{n}e}"


def dryrun_table(mesh, variant="opt"):
    suffix = "" if variant == "baseline" else f"__{variant}"
    cells = [c for c in load(f"*__{mesh}__step{suffix}.json")]
    print(f"\n### §Dry-run — {mesh} mesh (step granularity, shipped/{variant} code)\n")
    print("| arch | shape | status | compile_s | args GB/chip | temp GB/chip "
          "| collectives | wire MB/chip |")
    print("|---|---|---|---|---|---|---|---|")
    for c in cells:
        if c["status"] != "ok":
            print(f"| {c['arch']} | {c['shape']} | **{c['status']}** | | | | | |")
            continue
        m = c["memory"]
        ncoll = sum(v["count"] for v in c["collectives"].values())
        print(f"| {c['arch']} | {c['shape']} | ok | {c['compile_s']} | "
              f"{m['argument_bytes']/1e9:.2f} | {m['temp_bytes']/1e9:.2f} | "
              f"{ncoll} | {c['wire_bytes_per_chip']/1e6:.1f} |")


def roofline_table(variant):
    suffix = "" if variant == "baseline" else f"__{variant}"
    cells = [c for c in load(f"*__single__layer{suffix}.json")]
    print(f"\n### §Roofline — {variant} (layer granularity, single pod, 256 chips)\n")
    print("| arch | shape | compute_s | memory_s | collective_s | bottleneck "
          "| MODEL/HLO | roofline frac |")
    print("|---|---|---|---|---|---|---|---|")
    for c in cells:
        if c.get("status") != "ok" or "roofline" not in c:
            print(f"| {c.get('arch')} | {c.get('shape')} | ERROR | | | | | |")
            continue
        r = c["roofline"]
        print(f"| {c['arch']} | {c['shape']} | {fmt(r['compute_s'])} | "
              f"{fmt(r['memory_s'])} | {fmt(r['collective_s'])} | "
              f"{r['bottleneck']} | {r['useful_ratio']:.3f} | "
              f"{r['roofline_fraction']:.4f} |")


def perf_compare(cells_of_interest):
    print("\n### §Perf — baseline vs optimized (three hillclimb cells)\n")
    print("| cell | variant | compute_s | memory_s | collective_s | bound_s | Δ bound |")
    print("|---|---|---|---|---|---|---|")
    for arch, shape in cells_of_interest:
        base = opt = None
        for c in load(f"{arch}__{shape}__single__layer.json"):
            base = c
        for c in load(f"{arch}__{shape}__single__layer__opt.json"):
            opt = c
        rows = []
        for tag, c in (("baseline", base), ("opt", opt)):
            if c is None or c.get("status") != "ok":
                continue
            r = c["roofline"]
            bound = max(r["compute_s"], r["memory_s"], r["collective_s"])
            rows.append((tag, r, bound))
        for tag, r, bound in rows:
            delta = ""
            if tag == "opt" and len(rows) == 2:
                delta = f"{rows[0][2] / bound:.1f}×"
            print(f"| {arch} × {shape} | {tag} | {fmt(r['compute_s'])} | "
                  f"{fmt(r['memory_s'])} | {fmt(r['collective_s'])} | "
                  f"{fmt(bound)} | {delta} |")


if __name__ == "__main__":
    dryrun_table("single")
    dryrun_table("multi")
    roofline_table("baseline")
    roofline_table("opt")
    perf_compare([("qwen3-8b", "decode_32k"), ("arctic-480b", "decode_32k"),
                  ("granite-moe-3b-a800m", "train_4k")])
